//! The epoch planner: the full §5 decision loop as a pure function of a
//! [`ClusterView`].
//!
//! Decision order is the original simulator epoch's, preserved exactly so
//! same-seed simulator runs stay bit-identical (`tests/golden_stats.rs`):
//!
//! 1. **Repairs** (§5.2), one action per affected range, in failure
//!    detection order — repairs trump balancing.
//! 2. **Hot splits** (§4.1.1/§5.1, when `split_hot`): records hotter than
//!    8x the per-record mean divide at a prefix-aligned midpoint.
//! 3. **Migration** (§5.1, when `migration`): greedy — while some live
//!    node's load share exceeds both `overload_factor / num_nodes` and
//!    the uniform share by >4 sigma of the epoch's sampling noise, move
//!    its hottest sub-range to the least-utilized node outside the chain.
//!
//! The planner mutates its own working copies of the directory and the
//! counters as it plans, so every decision sees its predecessors exactly
//! the way the executor will after applying the ops in order.

use crate::chain::repair_chain;
use crate::config::ControllerConfig;
use crate::partition::Directory;
use crate::types::{Key, NodeId};

use super::estimator::{estimate_loads, LoadEstimator};
use super::ops::{ControlOp, Intent, NothingReason, Plan, PlanAction};
use super::view::ClusterView;

/// One data copy required by a chain repair: the new tail `dst` must
/// receive the sub-range's pairs from the surviving replica `src`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CopyPlan {
    pub src: NodeId,
    pub dst: NodeId,
}

/// The repair decision for one affected sub-range — pure planning, also
/// usable on its own (the deployment tests exercise it directly). The
/// caller applies it: perform the data copy, install `new_chain` in the
/// directory, push it to the switches.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RangeRepairPlan {
    pub new_chain: Vec<NodeId>,
    pub copy: Option<CopyPlan>,
}

/// Plan the §5.2 repair of sub-range `idx` after `failed` died: drop the
/// failed node from the chain, append the least-loaded live replacement
/// (if any node outside the chain survives), and name the surviving
/// replica the replacement must copy from. `alive[n]` is the controller's
/// current liveness view.
pub fn plan_range_repair(
    dir: &Directory,
    alive: &[bool],
    idx: usize,
    failed: NodeId,
) -> RangeRepairPlan {
    let chain = dir.chain(idx).to_vec();
    let replacement = least_loaded_replacement(dir, alive, &chain, failed);
    let repair = repair_chain(&chain, failed, replacement);
    let copy = repair.needs_copy.and_then(|dst| {
        repair
            .new_chain
            .iter()
            .copied()
            .find(|&n| n != dst && alive[n])
            .map(|src| CopyPlan { src, dst })
    });
    RangeRepairPlan { new_chain: repair.new_chain, copy }
}

fn least_loaded_replacement(
    dir: &Directory,
    alive: &[bool],
    chain: &[NodeId],
    failed: NodeId,
) -> Option<NodeId> {
    (0..alive.len())
        .filter(|&n| alive[n] && n != failed && !chain.contains(&n))
        .min_by_key(|&n| dir.ranges_of_node(n).len())
}

/// Plan one controller epoch over `view`. Deterministic: the same view
/// (and a deterministic estimator) always produces the same plan.
pub fn plan_epoch(view: ClusterView, est: &mut dyn LoadEstimator) -> Plan {
    let ClusterView { dir, read, write, hits, alive, failures, knobs } = view;
    // Executors without switch-cache telemetry may send an empty (or
    // stale-shaped) hits vector; a shape mismatch means zero hits.
    let hits = if hits.len() == read.len() { hits } else { vec![0; read.len()] };
    let mut p = Planner { dir, read, write, hits, alive, knobs, est, actions: Vec::new() };
    for failed in failures {
        // Marked dead at its turn: a node that fails later in the list is
        // still a valid replacement for one that failed earlier.
        p.alive[failed] = false;
        p.plan_repairs(failed);
    }
    let load = p.plan_balancing();
    Plan { actions: p.actions, load }
}

struct Planner<'a> {
    dir: Directory,
    read: Vec<u64>,
    write: Vec<u64>,
    hits: Vec<u64>,
    alive: Vec<bool>,
    knobs: ControllerConfig,
    est: &'a mut dyn LoadEstimator,
    actions: Vec<PlanAction>,
}

impl Planner<'_> {
    /// Reads the storage nodes actually served: switch-cache hits never
    /// reach a chain tail, so they are subtracted from the raw
    /// coordinator counts before estimating node load (§5.1). The raw
    /// counts still drive hot-range *splits* — the switch routes (and
    /// counts) every request whether or not its cache absorbed it.
    fn served_reads(&self) -> Vec<u64> {
        self.read.iter().zip(&self.hits).map(|(&r, &h)| r.saturating_sub(h)).collect()
    }

    fn note(&mut self, reason: NothingReason) {
        self.actions.push(PlanAction {
            intent: Intent::Observe,
            ops: vec![ControlOp::Nothing { reason }],
        });
    }

    /// §5.2: one repair action per range the failed node served.
    fn plan_repairs(&mut self, failed: NodeId) {
        for idx in self.dir.ranges_of_node(failed) {
            let plan = plan_range_repair(&self.dir, &self.alive, idx, failed);
            let mut ops = Vec::with_capacity(2);
            if let Some(copy) = plan.copy {
                let (start, end) = self.dir.bounds(idx);
                ops.push(ControlOp::CopyRange {
                    from: copy.src,
                    to: copy.dst,
                    span: (start, end),
                });
            }
            self.dir.set_chain(idx, plan.new_chain.clone());
            ops.push(ControlOp::SetChain { idx, chain: plan.new_chain });
            self.actions.push(PlanAction { intent: Intent::Repair { failed, idx }, ops });
        }
    }

    /// §5.1 load balancing; returns the load estimate it was based on
    /// (`None` when migration is disabled and no estimate was computed).
    fn plan_balancing(&mut self) -> Option<Vec<f32>> {
        if !self.knobs.migration {
            self.note(NothingReason::MigrationDisabled);
            return None;
        }
        // Optional §4.1.1/§5.1 sub-range division: very hot records are
        // split at a prefix-aligned midpoint first, so migration can move
        // "a subset of the hot data in a sub-range" instead of the whole
        // record.
        if self.knobs.split_hot {
            self.plan_splits();
        }
        let num_nodes = self.alive.len();
        let served = self.served_reads();
        let load = estimate_loads(
            self.est,
            &self.dir,
            &served,
            &self.write,
            num_nodes,
            self.knobs.write_cost as f32,
        );
        let total: f32 = load.iter().sum();
        if total <= 0.0 {
            self.note(NothingReason::NoTraffic);
            return Some(load);
        }
        // A node is over-utilized when its load share exceeds both the
        // configured factor AND the uniform share by >4 sigma of the
        // epoch's multinomial sampling noise — small epochs must not
        // migrate on noise.
        let samples: u64 = self.read.iter().sum::<u64>() + self.write.iter().sum::<u64>();
        let uniform_share = 1.0f32 / num_nodes as f32;
        let sigma = (uniform_share * (1.0 - uniform_share) / (samples.max(1) as f32)).sqrt();
        let threshold = (self.knobs.overload_factor as f32 * uniform_share)
            .max(uniform_share + 4.0 * sigma);

        for _ in 0..self.knobs.max_migrations_per_epoch {
            // Greedy: most-loaded live node above threshold.
            let hot = self
                .load_ranked()
                .into_iter()
                .find(|&(n, share)| self.alive[n] && share > threshold);
            let Some((hot_node, _)) = hot else {
                self.note(NothingReason::NoOverload);
                break;
            };
            if !self.plan_migrate_one(hot_node) {
                break;
            }
        }
        Some(load)
    }

    /// §4.1.1/§5.1 sub-range division: split any record whose hit count
    /// is > 8x the per-record mean at a prefix-aligned midpoint. Both
    /// halves keep the original chain (no data moves — migration may then
    /// move one half); counters are halved across the split.
    fn plan_splits(&mut self) {
        let total: u64 = self.read.iter().sum::<u64>() + self.write.iter().sum::<u64>();
        if total == 0 {
            return;
        }
        let mut i = 0;
        while i < self.dir.len() {
            let mean = (total / self.dir.len() as u64).max(1);
            let weight = self.read[i] + self.write[i];
            let (start, end) = self.dir.bounds(i);
            // Midpoint in 32-bit-prefix space, kept 2^96-aligned so the
            // XLA dataplane's prefix matching stays exact.
            let lo = start.prefix32();
            let hi = end.prefix32();
            let splittable = start.is_prefix_aligned() && hi > lo + 1;
            if weight > 8 * mean && splittable {
                let mid = Key::from_prefix32(lo + (hi - lo) / 2 + 1);
                debug_assert!(mid > start && mid <= end);
                let chain = self.dir.chain(i).to_vec();
                self.dir.split(i, mid, chain.clone());
                // Halve the observed counters across the two halves.
                self.read.insert(i + 1, self.read[i] / 2);
                self.read[i] -= self.read[i + 1];
                self.write.insert(i + 1, self.write[i] / 2);
                self.write[i] -= self.write[i + 1];
                self.hits.insert(i + 1, self.hits[i] / 2);
                self.hits[i] -= self.hits[i + 1];
                self.actions.push(PlanAction {
                    intent: Intent::Split { idx: i },
                    ops: vec![ControlOp::SplitRecord { idx: i, at: mid, chain }],
                });
                // The still-hot halves get re-examined next epoch with
                // fresh counters.
            }
            i += 1;
        }
    }

    /// Per-node load shares, hottest first, recomputed from current
    /// chains.
    fn load_ranked(&mut self) -> Vec<(NodeId, f32)> {
        let num_nodes = self.alive.len();
        let served = self.served_reads();
        let load = estimate_loads(
            self.est,
            &self.dir,
            &served,
            &self.write,
            num_nodes,
            self.knobs.write_cost as f32,
        );
        let total: f32 = load.iter().sum::<f32>().max(1e-9);
        let mut ranked: Vec<(NodeId, f32)> =
            load.iter().enumerate().map(|(n, &l)| (n, l / total)).collect();
        ranked.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        ranked
    }

    /// Migrate the hottest sub-range served by `hot_node` to the
    /// least-utilized node (greedy selection, §5.1). Returns false if no
    /// migration applies.
    fn plan_migrate_one(&mut self, hot_node: NodeId) -> bool {
        // Hottest range where hot_node is the tail (reads) or any member.
        let mut candidate: Option<(usize, u64)> = None;
        for idx in self.dir.ranges_of_node(hot_node) {
            let weight = if self.dir.tail(idx) == hot_node {
                self.read[idx].saturating_sub(self.hits[idx]) + self.write[idx]
            } else {
                self.write[idx]
            };
            if weight > candidate.map(|(_, w)| w).unwrap_or(0) {
                candidate = Some((idx, weight));
            }
        }
        let Some((idx, weight)) = candidate else {
            self.note(NothingReason::NoHotRange);
            return false;
        };
        if weight == 0 {
            self.note(NothingReason::NoHotRange);
            return false;
        }
        // Least-utilized live node not already in the chain.
        let ranked = self.load_ranked();
        let chain = self.dir.chain(idx).to_vec();
        let Some(&(target, _)) = ranked
            .iter()
            .rev()
            .find(|&&(n, _)| self.alive[n] && !chain.contains(&n))
        else {
            self.note(NothingReason::NoMigrationTarget);
            return false;
        };

        // Physically move the sub-range's data (extract → ingest → delete
        // old copy, §5.1), then reconfigure the chain: target takes
        // hot_node's position.
        let (start, end) = self.dir.bounds(idx);
        let new_chain: Vec<NodeId> = chain
            .iter()
            .map(|&n| if n == hot_node { target } else { n })
            .collect();
        self.dir.set_chain(idx, new_chain.clone());
        self.actions.push(PlanAction {
            intent: Intent::Migrate { idx, from: hot_node, to: target },
            ops: vec![
                ControlOp::CopyRange { from: hot_node, to: target, span: (start, end) },
                ControlOp::DeleteRange { node: hot_node, span: (start, end) },
                ControlOp::SetChain { idx, chain: new_chain },
            ],
        });
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repair_plan_appends_replacement_and_names_copy_source() {
        // 4 nodes, r=3: killing a chain member leaves exactly one node
        // outside the chain as the replacement, which must receive a copy
        // from a surviving member.
        let dir = Directory::initial(8, 4, 3);
        let alive = vec![true, false, true, true];
        let idx = dir.ranges_of_node(1)[0];
        let chain = dir.chain(idx).to_vec();
        let plan = plan_range_repair(&dir, &alive, idx, 1);
        assert_eq!(plan.new_chain.len(), 3, "replication factor restored");
        assert!(!plan.new_chain.contains(&1), "failed node dropped");
        let copy = plan.copy.expect("new tail needs the sub-range's data");
        assert_eq!(Some(&copy.dst), plan.new_chain.last(), "copy lands on the new tail");
        assert!(chain.contains(&copy.src) && copy.src != 1, "copy from a surviving replica");
    }

    #[test]
    fn repair_plan_shortens_chain_when_no_spare_node_exists() {
        // 3 nodes, r=3: every live node is already in every chain, so the
        // repair can only shorten — no replacement, no copy.
        let dir = Directory::initial(6, 3, 3);
        let alive = vec![true, false, true];
        let plan = plan_range_repair(&dir, &alive, 0, 1);
        assert_eq!(plan.new_chain.len(), 2);
        assert!(!plan.new_chain.contains(&1));
        assert_eq!(plan.copy, None);
    }

    #[test]
    fn later_failure_still_serves_as_earlier_replacement() {
        // Nodes 1 and 3 fail in the same epoch, in that order. When node
        // 1's ranges are repaired, node 3 has not been marked dead yet, so
        // it may be chosen as a replacement — exactly the original epoch
        // handler's interleaving. The repair of node 3's ranges then runs
        // with node 3 dead and must undo nothing.
        use crate::control::estimator::RustEstimator;
        let dir = Directory::initial(8, 5, 3);
        let view = ClusterView {
            dir: dir.clone(),
            read: vec![0; 8],
            write: vec![0; 8],
            hits: vec![],
            // Node 1 already marked (its failure event preceded the
            // epoch); node 3 still alive until its turn.
            alive: vec![true, false, true, true, true],
            failures: vec![1, 3],
            knobs: ControllerConfig::default(),
        };
        let plan = plan_epoch(view, &mut RustEstimator);
        // Every planned chain must exclude node 1; chains planned after
        // node 3's turn must exclude node 3 too. Verify the end state by
        // replaying the plan onto the directory.
        let mut replay = dir;
        for op in plan.ops() {
            op.apply_to_directory(&mut replay);
        }
        for i in 0..replay.len() {
            assert!(!replay.chain(i).contains(&1), "range {i} kept failed node 1");
            assert!(!replay.chain(i).contains(&3), "range {i} kept failed node 3");
        }
        replay.check_invariants().unwrap();
        assert!(plan.repairs() > 0);
    }

    #[test]
    fn cache_hits_are_subtracted_from_node_load() {
        use crate::control::estimator::RustEstimator;
        // Range 0 is extremely hot at the coordinator switch. When the
        // nodes actually served that heat, its tail is overloaded and
        // migration fires; when the switch value cache absorbed (almost)
        // all of it, node-side load is near uniform and nothing moves.
        let dir = Directory::initial(8, 4, 3);
        let mut knobs = ControllerConfig::default();
        knobs.migration = true;
        let mut read = vec![10u64; 8];
        read[0] = 100_000;
        let view = |hits: Vec<u64>| ClusterView {
            dir: dir.clone(),
            read: read.clone(),
            write: vec![0; 8],
            hits,
            alive: vec![true; 4],
            failures: vec![],
            knobs: knobs.clone(),
        };
        let plan = plan_epoch(view(vec![0; 8]), &mut RustEstimator);
        assert!(
            plan.actions.iter().any(|a| matches!(a.intent, Intent::Migrate { .. })),
            "node-served heat must trigger migration"
        );
        let mut hits = vec![0u64; 8];
        hits[0] = 99_990;
        let plan = plan_epoch(view(hits), &mut RustEstimator);
        assert!(
            !plan.actions.iter().any(|a| matches!(a.intent, Intent::Migrate { .. })),
            "switch-absorbed reads are not node load"
        );
    }
}
