//! The control-plane decision core (paper §5): one pure, deterministic
//! planner shared by every executor.
//!
//! TurboKV's controller makes three kinds of decisions — failure repair
//! (§5.2), statistics-driven hot-range migration (§5.1), and hot-range
//! division (§4.1.1/§5.1). Before this module existed those decisions were
//! interleaved with their *application* inside the simulator's epoch
//! handler, and the real-socket deployment carried a parallel, repair-only
//! reimplementation that could never migrate. Now the split is explicit:
//!
//! * [`view::ClusterView`] — everything the controller is allowed to see:
//!   a directory snapshot, the per-range read/write counters drained from
//!   the switch registers this epoch, its liveness view, and the
//!   `[controller]` config knobs.
//! * [`planner::plan_epoch`] — consumes a view (plus a
//!   [`LoadEstimator`]) and emits a [`Plan`] of typed [`ControlOp`]s:
//!   `SetChain`, `SplitRecord`, `CopyRange`, `DeleteRange`, and explicit
//!   no-ops with reasons. The planner never touches a socket, a node, or
//!   a switch — it is a pure function of the view, so the same view
//!   always yields the same plan (the property tests pin this).
//! * **Executors** apply the ops: `cluster::controller::run_epoch` maps
//!   them onto the simulated world (direct extract/ingest calls, switch
//!   tables mutated in place), and `deploy::harness`'s epoch loop maps
//!   the *same* ops onto the TCP control codec
//!   (`ExtractRange`/`IngestRange`/`SetChain`/`SplitRecord`), which is
//!   what gives the deployment live data migration and hot-range
//!   splitting.
//!
//! The planner's decision sequence is a faithful extraction of the
//! original simulator epoch (repairs first, then optional hot splits,
//! then greedy migration off >4-sigma over-utilized nodes), preserved
//! bit-for-bit so same-seed simulator runs produce identical `RunStats`.

pub mod estimator;
pub mod ops;
pub mod planner;
pub mod view;

pub use estimator::{estimate_loads, LoadEstimator, RustEstimator};
pub use ops::{ControlOp, Intent, NothingReason, Plan, PlanAction};
pub use planner::{plan_epoch, plan_range_repair, CopyPlan, RangeRepairPlan};
pub use view::ClusterView;
