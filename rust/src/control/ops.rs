//! Typed control-plane operations and the epoch plan that carries them.
//!
//! A [`ControlOp`] is one primitive the control plane can ask the world
//! to perform; a [`PlanAction`] groups the ops of one *decision* (one
//! repaired range, one migration, one split) under its [`Intent`] so an
//! executor can apply — or abort — a decision as a unit; a [`Plan`] is
//! one epoch's ordered list of actions. Ops are pure data: applying them
//! is the executor's business (direct calls in the simulator, control
//! sockets in the deployment).

use crate::partition::Directory;
use crate::types::{Key, NodeId};

/// One primitive control-plane operation. Range indexes refer to the
/// directory state produced by applying all *earlier* ops of the same
/// plan in order (the planner evolves its working directory exactly that
/// way while planning).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ControlOp {
    /// Copy every pair in `span` (inclusive bounds) from one node to
    /// another (repair restore, §5.2; migration data move, §5.1).
    CopyRange { from: NodeId, to: NodeId, span: (Key, Key) },
    /// Drop `span`'s pairs from a node — "after the sub-range's data is
    /// migrated ... the old copy is removed" (§5.1).
    DeleteRange { node: NodeId, span: (Key, Key) },
    /// Install a new replica chain for range `idx` in the directory and
    /// every switch table.
    SetChain { idx: usize, chain: Vec<NodeId> },
    /// Split range `idx` at `at`; the new upper record keeps `chain`.
    /// Executors must also insert a counter slot at `idx + 1` in every
    /// switch's register arrays.
    SplitRecord { idx: usize, at: Key, chain: Vec<NodeId> },
    /// Deliberate inaction, with the reason (observability: an empty
    /// epoch is a decision too).
    Nothing { reason: NothingReason },
}

impl ControlOp {
    /// Apply this op's directory-visible effect (data-movement ops have
    /// none). Executors use this to keep their authoritative directory in
    /// lock-step with the switch tables; tests use it to check that a
    /// plan preserves the key-space partition.
    pub fn apply_to_directory(&self, dir: &mut Directory) {
        match self {
            ControlOp::SetChain { idx, chain } => dir.set_chain(*idx, chain.clone()),
            ControlOp::SplitRecord { idx, at, chain } => {
                dir.split(*idx, *at, chain.clone());
            }
            ControlOp::CopyRange { .. }
            | ControlOp::DeleteRange { .. }
            | ControlOp::Nothing { .. } => {}
        }
    }

    /// Does this op change any state when applied?
    pub fn is_effectful(&self) -> bool {
        !matches!(self, ControlOp::Nothing { .. })
    }
}

/// Why the planner deliberately did nothing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NothingReason {
    /// `controller.migration` is off; only repairs are ever planned.
    MigrationDisabled,
    /// No counter mass this epoch — nothing to balance on.
    NoTraffic,
    /// No live node's load share clears the overload threshold (which
    /// includes the >4-sigma sampling-noise guard).
    NoOverload,
    /// The over-utilized node serves no range with observed traffic.
    NoHotRange,
    /// Every live node already belongs to the hot range's chain.
    NoMigrationTarget,
}

/// What one action is *for* — the decision level above its ops.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Intent {
    /// §5.2: re-form range `idx`'s chain after `failed` died.
    Repair { failed: NodeId, idx: usize },
    /// §4.1.1/§5.1: divide hot range `idx`.
    Split { idx: usize },
    /// §5.1: move range `idx` off over-utilized `from` onto `to`.
    Migrate { idx: usize, from: NodeId, to: NodeId },
    /// Nothing to do (the ops carry the reason).
    Observe,
}

/// One decision and the ops that implement it. Executors apply the ops in
/// order; an executor that cannot complete an action (a dead control
/// socket mid-migration) skips or aborts at action granularity, never
/// half-applies a single decision's routing update.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PlanAction {
    pub intent: Intent,
    pub ops: Vec<ControlOp>,
}

/// One epoch's plan: ordered actions plus the load estimate they were
/// based on.
#[derive(Clone, Debug, PartialEq)]
pub struct Plan {
    pub actions: Vec<PlanAction>,
    /// The per-node load estimate computed this epoch; `None` when the
    /// balancing phase was skipped entirely (migration disabled).
    pub load: Option<Vec<f32>>,
}

impl Plan {
    pub fn ops(&self) -> impl Iterator<Item = &ControlOp> {
        self.actions.iter().flat_map(|a| a.ops.iter())
    }

    /// Does the plan change any state at all?
    pub fn has_effects(&self) -> bool {
        self.ops().any(ControlOp::is_effectful)
    }

    fn count(&self, f: impl Fn(&Intent) -> bool) -> u64 {
        self.actions.iter().filter(|a| f(&a.intent)).count() as u64
    }

    pub fn repairs(&self) -> u64 {
        self.count(|i| matches!(i, Intent::Repair { .. }))
    }

    pub fn migrations(&self) -> u64 {
        self.count(|i| matches!(i, Intent::Migrate { .. }))
    }

    pub fn splits(&self) -> u64 {
        self.count(|i| matches!(i, Intent::Split { .. }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_counts_by_intent() {
        let plan = Plan {
            actions: vec![
                PlanAction { intent: Intent::Repair { failed: 1, idx: 0 }, ops: vec![] },
                PlanAction { intent: Intent::Repair { failed: 1, idx: 3 }, ops: vec![] },
                PlanAction {
                    intent: Intent::Migrate { idx: 2, from: 0, to: 3 },
                    ops: vec![],
                },
                PlanAction {
                    intent: Intent::Observe,
                    ops: vec![ControlOp::Nothing { reason: NothingReason::NoOverload }],
                },
            ],
            load: None,
        };
        assert_eq!(plan.repairs(), 2);
        assert_eq!(plan.migrations(), 1);
        assert_eq!(plan.splits(), 0);
        assert!(!plan.has_effects(), "only data-free actions listed ops");
    }

    #[test]
    fn apply_to_directory_covers_routing_ops_only() {
        let mut dir = Directory::initial(4, 4, 2);
        let (start, end) = dir.bounds(1);
        let mid = Key(start.0 / 2 + end.0 / 2 + 1);
        ControlOp::SplitRecord { idx: 1, at: mid, chain: vec![2, 3] }.apply_to_directory(&mut dir);
        assert_eq!(dir.len(), 5);
        assert_eq!(dir.chain(2), &[2, 3]);
        ControlOp::SetChain { idx: 0, chain: vec![1, 2] }.apply_to_directory(&mut dir);
        assert_eq!(dir.chain(0), &[1, 2]);
        let before = dir.clone();
        ControlOp::CopyRange { from: 0, to: 1, span: (start, end) }.apply_to_directory(&mut dir);
        ControlOp::DeleteRange { node: 0, span: (start, end) }.apply_to_directory(&mut dir);
        ControlOp::Nothing { reason: NothingReason::NoTraffic }.apply_to_directory(&mut dir);
        assert_eq!(dir, before, "data ops leave the directory untouched");
        dir.check_invariants().unwrap();
    }
}
