//! The controller's input: a pure snapshot of everything §5's decision
//! loop is allowed to observe.

use crate::config::ControllerConfig;
use crate::partition::Directory;
use crate::types::NodeId;

/// One epoch's worth of controller-visible cluster state. Building a view
/// is the executor's job (the simulator reads its world structs, the
/// deployment controller drains counters and pings over TCP); planning on
/// it is [`plan_epoch`](crate::control::plan_epoch)'s job.
#[derive(Clone, Debug)]
pub struct ClusterView {
    /// Snapshot of the authoritative directory. The planner mutates its
    /// own copy as it plans, so later decisions see earlier ones exactly
    /// the way the executor will after applying the ops in order.
    pub dir: Directory,
    /// Per-range read counters drained from the coordinator switches this
    /// epoch (`dir.len()` entries).
    pub read: Vec<u64>,
    /// Per-range update counters, same shape as `read`.
    pub write: Vec<u64>,
    /// Per-range reads served straight from the switch value cache, same
    /// shape as `read` (every hit is also counted in `read`). Executors
    /// without hit telemetry may leave this empty — the planner treats a
    /// shape mismatch as zero hits.
    pub hits: Vec<u64>,
    /// Liveness as the controller currently believes it, with this
    /// epoch's `failures` *not yet all marked dead*: the planner marks
    /// each failure dead at its turn, so a node that died later in the
    /// list is still a valid repair replacement for one that died earlier
    /// (matching the original epoch handler's interleaving).
    pub alive: Vec<bool>,
    /// Nodes newly observed dead this epoch, in detection order.
    pub failures: Vec<NodeId>,
    /// The `[controller]` config section — the single knob set both the
    /// simulator and the deployment read.
    pub knobs: ControllerConfig,
}
