//! Chain replication (van Renesse & Schneider, OSDI'04) — the consistency
//! protocol TurboKV uses for every sub-range (paper §4.1.2).
//!
//! Reads go to the tail; writes enter at the head, propagate through each
//! successor, and the tail replies — (n+1) messages per write against the
//! classical primary-backup protocol's 2n (Fig. 6), which the ablation
//! bench A2 reproduces. This module holds the protocol-level logic and
//! bookkeeping; the message flow itself is driven by the cluster simulator.

use crate::types::NodeId;

/// A node's position in a chain.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    Head,
    Middle,
    Tail,
    /// Chains of length 1: the node is both head and tail.
    Solo,
    NotMember,
}

/// Role of `node` within `chain`.
pub fn role_of(chain: &[NodeId], node: NodeId) -> Role {
    let Some(pos) = chain.iter().position(|&n| n == node) else {
        return Role::NotMember;
    };
    match (pos, chain.len()) {
        (_, 1) => Role::Solo,
        (0, _) => Role::Head,
        (p, len) if p == len - 1 => Role::Tail,
        _ => Role::Middle,
    }
}

/// Messages needed to complete one write under chain replication:
/// head→…→tail hops plus the tail's reply (paper §4.1.2: "(n+1) instead of
/// (2n)").
pub fn cr_write_messages(chain_len: usize) -> usize {
    chain_len + 1
}

/// Messages for the classical primary-backup protocol: primary sends to
/// n-1 backups, collects n-1 acks, then replies (2n for n nodes counting
/// the request delivery + reply, per the paper's accounting).
pub fn pb_write_messages(chain_len: usize) -> usize {
    2 * chain_len
}

/// Chain repair after a node failure (paper §5.2): drop the failed node
/// (predecessor now forwards to the old successor); optionally extend with
/// a replacement at the tail to restore the replication factor.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Repair {
    pub new_chain: Vec<NodeId>,
    /// Node that must receive a copy of the sub-range's data (the new
    /// tail), if a replacement was appended.
    pub needs_copy: Option<NodeId>,
}

/// Compute the repaired chain. `replacement` is chosen by the controller
/// (a functional node not already in the chain).
pub fn repair_chain(chain: &[NodeId], failed: NodeId, replacement: Option<NodeId>) -> Repair {
    let mut new_chain: Vec<NodeId> = chain.iter().copied().filter(|&n| n != failed).collect();
    assert!(!new_chain.is_empty(), "chain lost its last replica");
    let needs_copy = match replacement {
        Some(r) if !new_chain.contains(&r) => {
            new_chain.push(r);
            Some(r)
        }
        _ => None,
    };
    Repair { new_chain, needs_copy }
}

/// Can the chain still serve after `failures` simultaneous failures?
/// (paper §4.1.2: "TurboKV can sustain up to (r-1) node failures").
pub fn sustains(replication: usize, failures: usize) -> bool {
    failures < replication
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roles() {
        let chain = [3usize, 7, 9];
        assert_eq!(role_of(&chain, 3), Role::Head);
        assert_eq!(role_of(&chain, 7), Role::Middle);
        assert_eq!(role_of(&chain, 9), Role::Tail);
        assert_eq!(role_of(&chain, 4), Role::NotMember);
        assert_eq!(role_of(&[5], 5), Role::Solo);
    }

    #[test]
    fn message_counts_match_paper() {
        // r=3: CR uses 4 messages, primary-backup 6.
        assert_eq!(cr_write_messages(3), 4);
        assert_eq!(pb_write_messages(3), 6);
        for n in 1..10 {
            assert!(cr_write_messages(n) <= pb_write_messages(n));
        }
    }

    #[test]
    fn repair_drops_failed_and_extends() {
        let r = repair_chain(&[1, 2, 3], 2, Some(8));
        assert_eq!(r.new_chain, vec![1, 3, 8]);
        assert_eq!(r.needs_copy, Some(8));
    }

    #[test]
    fn repair_head_and_tail_failures() {
        // Head fails: successor becomes the new head.
        let r = repair_chain(&[1, 2, 3], 1, None);
        assert_eq!(r.new_chain, vec![2, 3]);
        assert_eq!(r.needs_copy, None);
        // Tail fails: predecessor becomes the new tail.
        let r = repair_chain(&[1, 2, 3], 3, None);
        assert_eq!(r.new_chain, vec![1, 2]);
    }

    #[test]
    fn repair_skips_replacement_already_in_chain() {
        let r = repair_chain(&[1, 2, 3], 2, Some(3));
        assert_eq!(r.new_chain, vec![1, 3]);
        assert_eq!(r.needs_copy, None);
    }

    #[test]
    #[should_panic(expected = "last replica")]
    fn repair_refuses_to_empty_chain() {
        repair_chain(&[5], 5, None);
    }

    #[test]
    fn sustains_r_minus_one() {
        assert!(sustains(3, 0));
        assert!(sustains(3, 2));
        assert!(!sustains(3, 3));
        assert!(!sustains(1, 1));
    }
}
