//! XLA-backed dataplane engines: the Pallas `range_lookup` kernel as the
//! switch's batched match-action stage, and the `load_matmul` kernel as the
//! controller's load estimator — both executed via PJRT from compiled
//! `artifacts/*.hlo.txt` (DESIGN.md §Hardware-Adaptation).

use std::rc::Rc;

use anyhow::Result;

use crate::cluster::controller::LoadEstimator;
use crate::switch::{DataplaneLookup, MatchActionTable, RegisterArrays, RustLookup};
use crate::types::Key;

use super::Runtime;

const OP_READ: u32 = 0;
const OP_WRITE: u32 = 1;
const OP_PAD: u32 = 2;

/// Batched dataplane lookup through the compiled `dataplane.hlo.txt`.
///
/// Matching uses 32-bit key prefixes, which is exact while all table
/// boundaries stay `2^96`-aligned; if the table diverges from the compiled
/// shape (record count != compiled N) or alignment breaks, the engine
/// transparently falls back to the rust reference path and counts it.
pub struct XlaLookup {
    rt: Rc<Runtime>,
    fallback: RustLookup,
    pub batches: u64,
    pub fallback_batches: u64,
}

impl XlaLookup {
    pub fn new(rt: Rc<Runtime>) -> XlaLookup {
        XlaLookup { rt, fallback: RustLookup, batches: 0, fallback_batches: 0 }
    }

    fn lookup_xla(
        &mut self,
        starts: &[u32],
        regs: &mut RegisterArrays,
        mvs: &[Key],
        is_write: &[bool],
    ) -> Result<Vec<usize>> {
        let b = self.rt.manifest.batch;
        let starts_lit = xla::Literal::vec1(starts);
        let mut out = Vec::with_capacity(mvs.len());
        for chunk_start in (0..mvs.len()).step_by(b) {
            let chunk = &mvs[chunk_start..(chunk_start + b).min(mvs.len())];
            let wchunk = &is_write[chunk_start..chunk_start + chunk.len()];
            let mut keys = vec![0u32; b];
            let mut ops = vec![OP_PAD; b];
            for (i, (mv, &w)) in chunk.iter().zip(wchunk).enumerate() {
                keys[i] = mv.prefix32();
                ops[i] = if w { OP_WRITE } else { OP_READ };
            }
            let outputs = self.rt.dataplane.execute(&[
                xla::Literal::vec1(&keys),
                xla::Literal::vec1(&ops),
                starts_lit.clone(),
            ])?;
            let idx: Vec<i32> = outputs[0].to_vec()?;
            let read_hits: Vec<i32> = outputs[1].to_vec()?;
            let write_hits: Vec<i32> = outputs[2].to_vec()?;
            regs.add_deltas(&read_hits, &write_hits);
            out.extend(idx[..chunk.len()].iter().map(|&i| i as usize));
            self.batches += 1;
        }
        Ok(out)
    }
}

impl DataplaneLookup for XlaLookup {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn lookup_batch(
        &mut self,
        table: &MatchActionTable,
        regs: &mut RegisterArrays,
        mvs: &[Key],
        is_write: &[bool],
    ) -> Vec<usize> {
        let compiled_n = self.rt.manifest.num_ranges;
        match table.starts_prefix32() {
            Some(starts) if starts.len() == compiled_n => {
                match self.lookup_xla(&starts, regs, mvs, is_write) {
                    Ok(idxs) => idxs,
                    Err(_) => {
                        self.fallback_batches += 1;
                        self.fallback.lookup_batch(table, regs, mvs, is_write)
                    }
                }
            }
            _ => {
                self.fallback_batches += 1;
                self.fallback.lookup_batch(table, regs, mvs, is_write)
            }
        }
    }
}

/// Controller load estimation through the compiled `loadbalance.hlo.txt`.
pub struct XlaEstimator {
    rt: Rc<Runtime>,
    pub calls: u64,
    pub fallback_calls: u64,
}

impl XlaEstimator {
    pub fn new(rt: Rc<Runtime>) -> XlaEstimator {
        XlaEstimator { rt, calls: 0, fallback_calls: 0 }
    }

    fn estimate_xla(
        &mut self,
        read: &[f32],
        write: &[f32],
        tail: &[f32],
        member: &[f32],
        write_cost: f32,
    ) -> Result<Vec<f32>> {
        let n = self.rt.manifest.num_ranges as i64;
        let s = self.rt.manifest.num_nodes as i64;
        let outputs = self.rt.loadbalance.execute(&[
            xla::Literal::vec1(read),
            xla::Literal::vec1(write),
            xla::Literal::vec1(tail).reshape(&[n, s])?,
            xla::Literal::vec1(member).reshape(&[n, s])?,
            xla::Literal::from(write_cost),
        ])?;
        self.calls += 1;
        Ok(outputs[0].to_vec()?)
    }
}

impl LoadEstimator for XlaEstimator {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn estimate(
        &mut self,
        read: &[f32],
        write: &[f32],
        tail: &[f32],
        member: &[f32],
        num_nodes: usize,
        write_cost: f32,
    ) -> Vec<f32> {
        let m = &self.rt.manifest;
        if read.len() == m.num_ranges && num_nodes == m.num_nodes {
            if let Ok(loads) = self.estimate_xla(read, write, tail, member, write_cost) {
                return loads;
            }
        }
        self.fallback_calls += 1;
        crate::cluster::controller::RustEstimator.estimate(
            read, write, tail, member, num_nodes, write_cost,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::controller::RustEstimator;
    use crate::partition::Directory;
    use crate::util::rng::Rng;

    fn runtime() -> Option<Rc<Runtime>> {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            eprintln!("skipping: artifacts/ missing — run `make artifacts`");
            return None;
        }
        Some(Rc::new(Runtime::load("artifacts").unwrap()))
    }

    fn installed_table(dir: &Directory) -> (MatchActionTable, RegisterArrays) {
        let mut t = MatchActionTable::new();
        t.install_from_directory(dir);
        let mut regs = RegisterArrays::new();
        regs.resize_counters(t.len());
        (t, regs)
    }

    /// The pinning test: XLA dataplane == rust reference, bit for bit, on
    /// random batches over the paper's table shape.
    #[test]
    fn xla_lookup_matches_rust_reference() {
        let Some(rt) = runtime() else { return };
        let dir = Directory::initial(128, 16, 3);
        let (table, mut regs_xla) = installed_table(&dir);
        let (_, mut regs_rust) = installed_table(&dir);
        let mut xla_engine = XlaLookup::new(rt);
        let mut rust_engine = RustLookup;

        let mut rng = Rng::new(0xBA7C4);
        for round in 0..4 {
            let n = [1usize, 17, 256, 700][round];
            let mvs: Vec<Key> = (0..n).map(|_| Key(rng.next_u128())).collect();
            let is_write: Vec<bool> = (0..n).map(|_| rng.chance(0.3)).collect();
            let got = xla_engine.lookup_batch(&table, &mut regs_xla, &mvs, &is_write);
            let want = rust_engine.lookup_batch(&table, &mut regs_rust, &mvs, &is_write);
            assert_eq!(got, want, "round {round}");
        }
        assert_eq!(regs_xla.counters(), regs_rust.counters());
        assert_eq!(xla_engine.fallback_batches, 0);
        assert!(xla_engine.batches >= 4);
    }

    #[test]
    fn xla_lookup_falls_back_on_misaligned_table() {
        let Some(rt) = runtime() else { return };
        let dir = Directory::initial(128, 16, 3);
        let (mut table, mut regs) = installed_table(&dir);
        // Misaligned split: prefix export fails, engine must fall back —
        // also changes the record count, either reason suffices.
        let (s, e) = table.bounds(0);
        table.split(0, Key(s.0 + (e.0 - s.0) / 3 + 1), vec![1, 2]);
        regs.insert_counter_slot(1);
        let mut engine = XlaLookup::new(rt);
        let mvs = vec![Key(0), Key(u128::MAX)];
        let idxs = engine.lookup_batch(&table, &mut regs, &mvs, &[false, false]);
        assert_eq!(idxs, vec![0, table.len() - 1]);
        assert_eq!(engine.fallback_batches, 1);
    }

    #[test]
    fn xla_estimator_matches_rust_reference() {
        let Some(rt) = runtime() else { return };
        let dir = Directory::initial(128, 16, 3);
        let (tail, member) = dir.onehot(16);
        let mut rng = Rng::new(77);
        let read: Vec<f32> = (0..128).map(|_| rng.gen_range(1000) as f32).collect();
        let write: Vec<f32> = (0..128).map(|_| rng.gen_range(500) as f32).collect();
        let mut xla_est = XlaEstimator::new(rt);
        let got = xla_est.estimate(&read, &write, &tail, &member, 16, 3.0);
        let want = RustEstimator.estimate(&read, &write, &tail, &member, 16, 3.0);
        assert_eq!(got.len(), 16);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() <= 1e-2 * w.abs().max(1.0), "{g} vs {w}");
        }
        assert_eq!(xla_est.fallback_calls, 0);
    }

    #[test]
    fn xla_estimator_falls_back_on_shape_mismatch() {
        let Some(rt) = runtime() else { return };
        let mut est = XlaEstimator::new(rt);
        // 8 ranges != compiled 128: must fall back, still correct.
        let read = vec![1.0f32; 8];
        let write = vec![0.0f32; 8];
        let tail = vec![1.0f32; 8 * 4];
        let member = vec![1.0f32; 8 * 4];
        let got = est.estimate(&read, &write, &tail, &member, 4, 2.0);
        assert_eq!(got, vec![8.0f32; 4]);
        assert_eq!(est.fallback_calls, 1);
    }
}
