//! Minimal JSON parser for `artifacts/manifest.json` (no serde offline —
//! DESIGN.md §3 dependency note). Supports objects, arrays, strings,
//! integers/floats, booleans and null; no escapes beyond `\" \\ \/ \n \t`.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
}

pub fn parse(text: &str) -> Result<Json> {
    let bytes = text.as_bytes();
    let mut pos = 0usize;
    let v = value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        bail!("trailing data at byte {pos}");
    }
    Ok(v)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && (b[*pos] as char).is_ascii_whitespace() {
        *pos += 1;
    }
}

fn value(b: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        bail!("unexpected end of input");
    }
    match b[*pos] {
        b'{' => object(b, pos),
        b'[' => array(b, pos),
        b'"' => Ok(Json::Str(string(b, pos)?)),
        b't' => lit(b, pos, "true", Json::Bool(true)),
        b'f' => lit(b, pos, "false", Json::Bool(false)),
        b'n' => lit(b, pos, "null", Json::Null),
        _ => number(b, pos),
    }
}

fn lit(b: &[u8], pos: &mut usize, word: &str, v: Json) -> Result<Json> {
    if b[*pos..].starts_with(word.as_bytes()) {
        *pos += word.len();
        Ok(v)
    } else {
        bail!("bad literal at byte {pos}");
    }
}

fn number(b: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    while *pos < b.len() && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E') {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos])?;
    Ok(Json::Num(s.parse::<f64>().with_context(|| format!("bad number {s:?}"))?))
}

fn string(b: &[u8], pos: &mut usize) -> Result<String> {
    debug_assert_eq!(b[*pos], b'"');
    *pos += 1;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                if *pos >= b.len() {
                    break;
                }
                out.push(match b[*pos] {
                    b'"' => '"',
                    b'\\' => '\\',
                    b'/' => '/',
                    b'n' => '\n',
                    b't' => '\t',
                    other => bail!("unsupported escape \\{}", other as char),
                });
                *pos += 1;
            }
            c => {
                out.push(c as char);
                *pos += 1;
            }
        }
    }
    bail!("unterminated string");
}

fn object(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // {
    let mut map = BTreeMap::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(Json::Obj(map));
    }
    loop {
        skip_ws(b, pos);
        if *pos >= b.len() || b[*pos] != b'"' {
            bail!("expected object key at byte {pos}");
        }
        let key = string(b, pos)?;
        skip_ws(b, pos);
        if *pos >= b.len() || b[*pos] != b':' {
            bail!("expected ':' at byte {pos}");
        }
        *pos += 1;
        map.insert(key, value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(map));
            }
            _ => bail!("expected ',' or '}}' at byte {pos}"),
        }
    }
}

fn array(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // [
    let mut out = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(Json::Arr(out));
    }
    loop {
        out.push(value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(out));
            }
            _ => bail!("expected ',' or ']' at byte {pos}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let doc = r#"{
            "batch": 256,
            "num_ranges": 128,
            "artifacts": {
                "dataplane": {
                    "file": "dataplane.hlo.txt",
                    "inputs": [{"name": "keys", "shape": [256], "dtype": "u32"}]
                }
            },
            "flag": true,
            "nothing": null,
            "pi": 3.5
        }"#;
        let j = parse(doc).unwrap();
        assert_eq!(j.get("batch").unwrap().as_u64(), Some(256));
        let dp = j.get("artifacts").unwrap().get("dataplane").unwrap();
        assert_eq!(dp.get("file").unwrap().as_str(), Some("dataplane.hlo.txt"));
        let inputs = dp.get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(inputs[0].get("shape").unwrap().as_arr().unwrap()[0].as_u64(), Some(256));
        assert_eq!(j.get("flag"), Some(&Json::Bool(true)));
        assert_eq!(j.get("nothing"), Some(&Json::Null));
        assert_eq!(j.get("pi").unwrap().as_u64(), None);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("{").is_err());
        assert!(parse("[1, 2").is_err());
        assert!(parse("\"open").is_err());
        assert!(parse("{} extra").is_err());
        assert!(parse("{bad: 1}").is_err());
    }

    #[test]
    fn strings_with_escapes() {
        let j = parse(r#"{"s": "a\"b\\c\nd"}"#).unwrap();
        assert_eq!(j.get("s").unwrap().as_str(), Some("a\"b\\c\nd"));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(Default::default()));
    }
}
