//! PJRT runtime: load the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them from the rust request path.
//!
//! Interchange is HLO *text* (not serialized protos): jax >= 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md). Python runs only at
//! `make artifacts` time; this module is all that touches the artifacts at
//! run time.

pub mod json;
#[cfg(feature = "pjrt")]
pub mod xla_lookup;

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use json::Json;

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub batch: usize,
    pub num_ranges: usize,
    pub num_nodes: usize,
    pub dataplane_file: PathBuf,
    pub loadbalance_file: PathBuf,
}

impl Manifest {
    pub fn load(artifacts_dir: &str) -> Result<Manifest> {
        let dir = Path::new(artifacts_dir);
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {artifacts_dir}/manifest.json — run `make artifacts`"))?;
        let doc = json::parse(&text)?;
        let u = |k: &str| -> Result<usize> {
            Ok(doc
                .get(k)
                .and_then(Json::as_u64)
                .with_context(|| format!("manifest missing {k}"))? as usize)
        };
        let file = |k: &str| -> Result<PathBuf> {
            Ok(dir.join(
                doc.get("artifacts")
                    .and_then(|a| a.get(k))
                    .and_then(|a| a.get("file"))
                    .and_then(Json::as_str)
                    .with_context(|| format!("manifest missing artifacts.{k}.file"))?,
            ))
        };
        Ok(Manifest {
            batch: u("batch")?,
            num_ranges: u("num_ranges")?,
            num_nodes: u("num_nodes")?,
            dataplane_file: file("dataplane")?,
            loadbalance_file: file("loadbalance")?,
        })
    }
}

/// A compiled artifact ready to execute on the PJRT CPU client.
#[cfg(feature = "pjrt")]
pub struct Artifact {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

/// Shared PJRT client + the compiled artifacts.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    pub manifest: Manifest,
    pub dataplane: Artifact,
    pub loadbalance: Artifact,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Construct the CPU PJRT client and compile both artifacts.
    pub fn load(artifacts_dir: &str) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let compile = |path: &Path, name: &str| -> Result<Artifact> {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?;
            Ok(Artifact { exe, name: name.to_string() })
        };
        let dataplane = compile(&manifest.dataplane_file, "dataplane")?;
        let loadbalance = compile(&manifest.loadbalance_file, "loadbalance")?;
        Ok(Runtime { client, manifest, dataplane, loadbalance })
    }
}

#[cfg(feature = "pjrt")]
impl Artifact {
    /// Execute with the given input literals; returns the flattened tuple
    /// elements (aot.py lowers with `return_tuple=True`).
    pub fn execute(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self.exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple()?)
    }
}

/// Smoke check that the PJRT CPU client can be constructed.
#[cfg(feature = "pjrt")]
pub fn pjrt_smoke() -> Result<String> {
    let client = xla::PjRtClient::cpu()?;
    Ok(format!(
        "platform={} devices={}",
        client.platform_name(),
        client.device_count()
    ))
}

/// Without the `pjrt` feature there is no PJRT client at all; callers get
/// a clear error instead of a compile failure.
#[cfg(not(feature = "pjrt"))]
pub fn pjrt_smoke() -> Result<String> {
    anyhow::bail!(
        "turbokv was built without the `pjrt` feature; \
         rebuild with `cargo build --features pjrt` to execute XLA artifacts"
    )
}

/// Human-readable runtime status for `turbokv smoke`, meaningful under
/// both feature configurations. Returns the rendered report and whether
/// the full PJRT-runtime + artifacts check passed — callers gating on
/// smoke (scripts, CI) must treat `ok == false` as a failure.
pub fn smoke_report(artifacts_dir: &str) -> (String, bool) {
    use std::fmt::Write as _;
    let mut out = String::new();
    let mut ok = true;
    match pjrt_smoke() {
        Ok(info) => {
            let _ = writeln!(out, "pjrt: {info}");
        }
        Err(e) => {
            ok = false;
            let _ = writeln!(out, "pjrt unavailable: {e:#}");
        }
    }
    #[cfg(feature = "pjrt")]
    match Runtime::load(artifacts_dir) {
        Ok(rt) => {
            let _ = writeln!(
                out,
                "artifacts OK: batch={} ranges={} nodes={} ({} / {})",
                rt.manifest.batch,
                rt.manifest.num_ranges,
                rt.manifest.num_nodes,
                rt.dataplane.name,
                rt.loadbalance.name,
            );
        }
        Err(e) => {
            ok = false;
            let _ = writeln!(out, "artifacts missing ({e:#}); run `make artifacts`");
        }
    }
    #[cfg(not(feature = "pjrt"))]
    match Manifest::load(artifacts_dir) {
        Ok(m) => {
            let _ = writeln!(
                out,
                "manifest OK: batch={} ranges={} nodes={} \
                 (execution requires the `pjrt` feature)",
                m.batch, m.num_ranges, m.num_nodes,
            );
        }
        Err(e) => {
            let _ = writeln!(out, "artifacts missing ({e:#})");
        }
    }
    (out, ok)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Artifacts are produced by `make artifacts`; tests that need them are
    /// skipped (with a note) when the directory is absent so `cargo test`
    /// works standalone.
    pub fn artifacts_dir() -> Option<&'static str> {
        if std::path::Path::new("artifacts/manifest.json").exists() {
            Some("artifacts")
        } else {
            eprintln!("skipping: artifacts/ missing — run `make artifacts`");
            None
        }
    }

    #[test]
    fn manifest_parses_paper_shapes() {
        let Some(dir) = artifacts_dir() else { return };
        let m = Manifest::load(dir).unwrap();
        assert_eq!(m.batch, 256);
        assert_eq!(m.num_ranges, 128);
        assert_eq!(m.num_nodes, 16);
        assert!(m.dataplane_file.exists());
        assert!(m.loadbalance_file.exists());
    }

    #[test]
    fn manifest_missing_dir_is_helpful_error() {
        let err = Manifest::load("/nonexistent").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
