#!/usr/bin/env python3
"""Compare two BENCH_*.json files and flag hot-path regressions.

Usage:
    scripts/bench_diff.py BASE.json NEW.json [--threshold 0.05]

Exit status:
    0 — no regression (or nothing comparable: either file unrecorded)
    1 — at least one watched bench regressed by more than the threshold
    2 — usage / schema error

A bench "regresses" when its mean_ns grows by more than the threshold
relative to the base recording. Only the watched hot paths gate:
`switch/pipeline/*`, `sim/engine/100k-events*`, and `dataplane/*` (the
zero-copy data plane's writer-coalescing and cut-through forwarding
paths) — the paths the ROADMAP north-star ("as fast as the hardware
allows") and the acceptance criteria of ISSUEs 3 and 10 name. Everything
else is reported informationally.
"""

import argparse
import json
import sys

WATCH_PREFIXES = ("switch/pipeline/", "sim/engine/100k-events", "dataplane/")


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench_diff: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)


def flatten(doc):
    """{bench_name: mean_ns} over every target in the `benches` section."""
    out = {}
    for target, benches in doc.get("benches", {}).items():
        if not isinstance(benches, dict):
            continue
        for name, rec in benches.items():
            mean = rec.get("mean_ns") if isinstance(rec, dict) else None
            out[name] = mean
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("base", help="baseline BENCH_*.json")
    ap.add_argument("new", help="candidate BENCH_*.json")
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.05,
        help="relative mean_ns growth that counts as a regression (default 0.05)",
    )
    args = ap.parse_args()

    base_doc, new_doc = load(args.base), load(args.new)
    for label, doc, path in (("base", base_doc, args.base), ("new", new_doc, args.new)):
        if doc.get("status") == "unrecorded":
            print(f"bench_diff: {label} file {path} is status=unrecorded; nothing to compare")
            return 0

    base, new = flatten(base_doc), flatten(new_doc)
    regressions = []
    incomparable_watched = []
    rows = []
    for name in sorted(set(base) | set(new)):
        b, n = base.get(name), new.get(name)
        watched = name.startswith(WATCH_PREFIXES)
        if b is None or n is None or b <= 0:
            rows.append((name, b, n, None, watched, False))
            # A watched bench that the *base* recorded but the candidate
            # lost (or left null) would pass the gate vacuously — flag it.
            # A bench new in the candidate has no baseline yet: fine.
            if watched and name in base:
                incomparable_watched.append(name)
            continue
        delta = (n - b) / b
        regressed = watched and delta > args.threshold
        rows.append((name, b, n, delta, watched, regressed))
        if regressed:
            regressions.append((name, delta))

    for name, b, n, delta, watched, regressed in rows:
        mark = "WATCH" if watched else "     "
        if delta is None:
            print(f"  {mark}  {name:<44} base={b} new={n} (not comparable)")
        else:
            flag = "  << REGRESSION" if regressed else ""
            print(f"  {mark}  {name:<44} {b:>12.0f} -> {n:>12.0f} ns  ({delta:+.1%}){flag}")

    failed = False
    if incomparable_watched:
        # Both files claim recorded numbers, yet a gating bench has no
        # comparable pair (renamed, or mean_ns left null): that would let
        # the regression gate pass vacuously, so treat it as a failure.
        print(
            "bench_diff: watched bench(es) missing a comparable recording: "
            + ", ".join(incomparable_watched)
        )
        failed = True
    if regressions:
        worst = max(regressions, key=lambda r: r[1])
        print(
            f"bench_diff: {len(regressions)} watched bench(es) regressed "
            f"> {args.threshold:.0%} (worst: {worst[0]} at {worst[1]:+.1%})"
        )
        failed = True
    if failed:
        return 1
    print("bench_diff: no watched regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
