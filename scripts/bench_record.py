#!/usr/bin/env python3
"""Convert `cargo bench` report output into the BENCH_*.json schema.

The bench targets are plain reports (criterion is unavailable offline —
DESIGN.md §3); each line looks like

    switch/pipeline/batch64     time: [1.1ms 1.2ms 1.4ms]  ±0.1ms  thrpt: 52000 elem/s

This script parses those lines into the schema `scripts/bench_diff.py`
consumes, so CI can record a candidate file (uploaded as a workflow
artifact) and diff it against the committed baseline on every run.

Usage:
    scripts/bench_record.py --out BENCH_pr4.json \
        --target micro_switch=/tmp/bench_micro_switch.txt \
        --target micro_store=/tmp/bench_micro_store.txt \
        [--note "CI smoke at 5% scale"]

It also ingests the deployment load generator's machine-readable run
report (`turbokv drive --deploy.report_path=...`, schema
turbokv-loadgen-v1) via `--loadgen NAME=report.json`, flattening its
throughput and per-op-type percentiles into the same benches schema so
`bench_diff.py` can compare loadgen runs across PRs.

Exit status: 0 on success, 2 on usage/parse errors (a target file that
yields zero bench lines is an error — silence must not masquerade as a
recording).
"""

import argparse
import json
import re
import sys

LINE = re.compile(
    r"^\s*(?P<name>\S+)\s+time:\s*\[(?P<min>\S+)\s+(?P<mean>\S+)\s+(?P<max>\S+)\]"
    r"\s*±(?P<std>\S+)(?:\s+thrpt:\s*(?P<thrpt>[\d.]+)\s*elem/s)?\s*$"
)

UNITS = {"ns": 1.0, "us": 1e3, "ms": 1e6, "s": 1e9}


def parse_duration_ns(text):
    m = re.fullmatch(r"([\d.]+)(ns|us|ms|s)", text)
    if not m:
        raise ValueError(f"unparsable duration {text!r}")
    return float(m.group(1)) * UNITS[m.group(2)]


def parse_report(path):
    benches = {}
    try:
        with open(path) as f:
            lines = f.readlines()
    except OSError as e:
        print(f"bench_record: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)
    for line in lines:
        m = LINE.match(line)
        if not m:
            continue
        try:
            mean_ns = parse_duration_ns(m.group("mean"))
        except ValueError as e:
            print(f"bench_record: {path}: {e}", file=sys.stderr)
            sys.exit(2)
        thrpt = m.group("thrpt")
        benches[m.group("name")] = {
            "mean_ns": mean_ns,
            "elems_per_s": float(thrpt) if thrpt else None,
        }
    if not benches:
        print(f"bench_record: no bench lines found in {path}", file=sys.stderr)
        sys.exit(2)
    return benches


def parse_loadgen(path):
    """Flatten a turbokv-loadgen-v1 run report into bench entries."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        print(f"bench_record: cannot read loadgen report {path}: {e}", file=sys.stderr)
        sys.exit(2)
    if doc.get("schema") != "turbokv-loadgen-v1":
        print(
            f"bench_record: {path} is not a turbokv-loadgen-v1 report "
            f"(schema={doc.get('schema')!r})",
            file=sys.stderr,
        )
        sys.exit(2)
    throughput = doc.get("throughput_ops", 0)
    if not throughput:
        print(f"bench_record: {path} reports zero throughput", file=sys.stderr)
        sys.exit(2)
    mode = doc.get("mode", "unknown")
    benches = {
        f"{mode}/throughput": {
            "mean_ns": 1e9 / throughput,  # per-op service interval
            "elems_per_s": float(throughput),
        }
    }
    for op, h in sorted(doc.get("latency_us", {}).items()):
        if not h.get("count"):
            continue  # an op class the workload mix never issued
        for q in ("p50_us", "p99_us", "p999_us"):
            benches[f"{mode}/{op}/{q[:-3]}"] = {
                "mean_ns": h[q] * 1e3,
                "elems_per_s": None,
            }
    # Switch value-cache effectiveness (present only when the harness ran
    # with --switch.cache_slots>0 and patched the report). Recorded in
    # mean_ns so bench_diff renders run-to-run deltas; neither entry is a
    # watched (gating) prefix — higher is better here, and the CI floor
    # lives in deploy.min_cache_hit_rate, not in the bench diff.
    cache = doc.get("switch_cache")
    if cache:
        total = cache.get("hits", 0) + cache.get("misses", 0)
        if total:
            benches[f"{mode}/cache/hit_rate_pct"] = {
                "mean_ns": 100.0 * cache["hits"] / total,
                "elems_per_s": None,
            }
        benches[f"{mode}/cache/served_from_switch"] = {
            "mean_ns": float(cache.get("hits", 0)),
            "elems_per_s": None,
        }
    return benches


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", required=True, help="output BENCH_*.json path")
    ap.add_argument(
        "--target",
        action="append",
        default=[],
        metavar="NAME=REPORT",
        help="bench target name and its captured stdout (repeatable)",
    )
    ap.add_argument(
        "--loadgen",
        action="append",
        default=[],
        metavar="NAME=REPORT.json",
        help="deployment loadgen JSON report (turbokv-loadgen-v1, repeatable)",
    )
    ap.add_argument("--note", default="", help="free-form provenance note")
    args = ap.parse_args()
    if not args.target and not args.loadgen:
        print("bench_record: need at least one --target or --loadgen", file=sys.stderr)
        sys.exit(2)

    benches = {}
    regenerate = []
    for spec in args.target:
        if "=" not in spec:
            print(f"bench_record: --target wants NAME=REPORT, got {spec!r}", file=sys.stderr)
            sys.exit(2)
        name, path = spec.split("=", 1)
        benches[name] = parse_report(path)
        regenerate.append(f"cargo bench --bench {name}")
    for spec in args.loadgen:
        if "=" not in spec:
            print(
                f"bench_record: --loadgen wants NAME=REPORT.json, got {spec!r}",
                file=sys.stderr,
            )
            sys.exit(2)
        name, path = spec.split("=", 1)
        benches[name] = parse_loadgen(path)
        regenerate.append(
            "turbokv harness --deploy.report_path=... (see .github/workflows/ci.yml)"
        )

    doc = {
        "description": "Recorded by scripts/bench_record.py from cargo bench "
        "output and/or turbokv-loadgen-v1 run reports.",
        "regenerate": "cd rust && " + "; ".join(regenerate),
        "compare": "python3 scripts/bench_diff.py <BASE>.json <THIS>.json",
        "status": "recorded",
        "status_note": args.note,
        "benches": benches,
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    total = sum(len(b) for b in benches.values())
    print(f"bench_record: wrote {total} bench entries to {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
