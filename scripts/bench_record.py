#!/usr/bin/env python3
"""Convert `cargo bench` report output into the BENCH_*.json schema.

The bench targets are plain reports (criterion is unavailable offline —
DESIGN.md §3); each line looks like

    switch/pipeline/batch64     time: [1.1ms 1.2ms 1.4ms]  ±0.1ms  thrpt: 52000 elem/s

This script parses those lines into the schema `scripts/bench_diff.py`
consumes, so CI can record a candidate file (uploaded as a workflow
artifact) and diff it against the committed baseline on every run.

Usage:
    scripts/bench_record.py --out BENCH_pr4.json \
        --target micro_switch=/tmp/bench_micro_switch.txt \
        --target micro_store=/tmp/bench_micro_store.txt \
        [--note "CI smoke at 5% scale"]

Exit status: 0 on success, 2 on usage/parse errors (a target file that
yields zero bench lines is an error — silence must not masquerade as a
recording).
"""

import argparse
import json
import re
import sys

LINE = re.compile(
    r"^\s*(?P<name>\S+)\s+time:\s*\[(?P<min>\S+)\s+(?P<mean>\S+)\s+(?P<max>\S+)\]"
    r"\s*±(?P<std>\S+)(?:\s+thrpt:\s*(?P<thrpt>[\d.]+)\s*elem/s)?\s*$"
)

UNITS = {"ns": 1.0, "us": 1e3, "ms": 1e6, "s": 1e9}


def parse_duration_ns(text):
    m = re.fullmatch(r"([\d.]+)(ns|us|ms|s)", text)
    if not m:
        raise ValueError(f"unparsable duration {text!r}")
    return float(m.group(1)) * UNITS[m.group(2)]


def parse_report(path):
    benches = {}
    try:
        with open(path) as f:
            lines = f.readlines()
    except OSError as e:
        print(f"bench_record: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)
    for line in lines:
        m = LINE.match(line)
        if not m:
            continue
        try:
            mean_ns = parse_duration_ns(m.group("mean"))
        except ValueError as e:
            print(f"bench_record: {path}: {e}", file=sys.stderr)
            sys.exit(2)
        thrpt = m.group("thrpt")
        benches[m.group("name")] = {
            "mean_ns": mean_ns,
            "elems_per_s": float(thrpt) if thrpt else None,
        }
    if not benches:
        print(f"bench_record: no bench lines found in {path}", file=sys.stderr)
        sys.exit(2)
    return benches


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", required=True, help="output BENCH_*.json path")
    ap.add_argument(
        "--target",
        action="append",
        required=True,
        metavar="NAME=REPORT",
        help="bench target name and its captured stdout (repeatable)",
    )
    ap.add_argument("--note", default="", help="free-form provenance note")
    args = ap.parse_args()

    benches = {}
    for spec in args.target:
        if "=" not in spec:
            print(f"bench_record: --target wants NAME=REPORT, got {spec!r}", file=sys.stderr)
            sys.exit(2)
        name, path = spec.split("=", 1)
        benches[name] = parse_report(path)

    doc = {
        "description": "Recorded by scripts/bench_record.py from cargo bench output.",
        "regenerate": "cd rust && cargo bench --bench "
        + " --bench ".join(sorted(benches)),
        "compare": "python3 scripts/bench_diff.py <BASE>.json <THIS>.json",
        "status": "recorded",
        "status_note": args.note,
        "benches": benches,
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    total = sum(len(b) for b in benches.values())
    print(f"bench_record: wrote {total} bench entries to {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
