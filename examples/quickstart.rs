//! Quickstart: build the paper's testbed (16 storage nodes, 4 clients,
//! 8 switches), run a small mixed YCSB-style workload with TurboKV's
//! in-switch coordination, and print the latency/throughput summary.
//!
//!     cargo run --release --offline --example quickstart

use turbokv::cluster::Cluster;
use turbokv::config::Config;

fn main() {
    let mut cfg = Config::default();
    // A 50/30/20 read/write/scan mix over 20k keys, zipf-0.99 popularity.
    cfg.workload.write_ratio = 0.3;
    cfg.workload.scan_ratio = 0.2;
    cfg.workload.zipf_theta = Some(0.99);
    cfg.workload.ops_per_client = 1_000;

    println!(
        "cluster: {} storage nodes in {} racks, {} switches, {} clients",
        cfg.cluster.nodes(),
        cfg.cluster.racks,
        cfg.cluster.racks + (cfg.cluster.racks / 2).max(1) + 2,
        cfg.cluster.clients
    );
    println!(
        "directory: {} sub-ranges, chain length {}\n",
        cfg.cluster.num_ranges, cfg.cluster.replication
    );

    let mut cl = Cluster::build(cfg);
    cl.verify_reads = true;
    let stats = cl.run().expect("run failed");

    println!("{}", cl.metrics.summary());
    println!(
        "switch passes keyrouted {} packets; {} simulation events",
        cl.switches.iter().map(|s| s.stats.keyrouted).sum::<u64>(),
        stats.events
    );
    assert_eq!(cl.metrics.errors, 0);
    println!("\nquickstart OK");
}
