//! Hierarchical indexing demo (paper §6): scale the cluster from one rack
//! to eight. AGG/Core/Edge switches route by key toward the right ToR
//! (no chain headers); only the target's ToR performs full coordinator
//! processing. Reports throughput and hop-count effects.
//!
//!     cargo run --release --offline --example multi_rack

use turbokv::cluster::Cluster;
use turbokv::config::Config;
use turbokv::net::topology::Addr;
use turbokv::types::OpCode;

fn main() {
    println!("racks  nodes  switches  throughput(ops/s)  read-mean(ms)  max-hops");
    for racks in [1usize, 2, 4, 8] {
        let mut cfg = Config::default();
        cfg.cluster.racks = racks;
        cfg.cluster.nodes_per_rack = 4;
        cfg.workload.zipf_theta = Some(0.99);
        cfg.workload.ops_per_client = 1_200;
        let switches = racks + (racks / 2).max(1) + 2;
        let mut cl = Cluster::build(cfg);
        let max_hops = (0..cl.topo.num_nodes)
            .map(|n| cl.topo.hops(Addr::Client(0), Addr::Node(n)).expect("routable"))
            .max()
            .unwrap();
        cl.run().expect("run failed");
        let (mean, _, _) = cl.metrics.latency_stats_ms(OpCode::Get).unwrap();
        println!(
            "{racks:<6} {:<6} {switches:<9} {:>17.1} {mean:>14.1} {max_hops:>9}",
            cl.topo.num_nodes,
            cl.metrics.throughput(),
        );
    }
    println!("\nmulti_rack OK");
}
