//! Failure handling demo (paper §5.2): two storage nodes fail mid-run
//! (r-1 = 2, the sustainable maximum). Dropped requests retransmit, the
//! controller removes the failed nodes from every chain, re-replicates the
//! affected sub-ranges onto live nodes, and the run completes with every
//! chain back at full replication.
//!
//!     cargo run --release --offline --example failure_recovery

use turbokv::cluster::Cluster;
use turbokv::config::Config;

fn main() {
    let mut cfg = Config::default();
    cfg.workload.ops_per_client = 2_000;
    cfg.controller.epoch_ns = 250_000_000; // fast failure detection
    let replication = cfg.cluster.replication;
    let mut cl = Cluster::build(cfg);
    cl.timeout_ns = 1_500_000_000;
    cl.schedule_node_failure(3, 800_000_000);
    cl.schedule_node_failure(9, 2_000_000_000);
    println!("nodes 3 and 9 will fail at t=0.8s and t=2.0s (sim time)...\n");

    let stats = cl.run().expect("run failed");
    println!("{}", cl.metrics.summary());
    println!(
        "repairs={} retransmissions={} epochs={}",
        stats.repairs, stats.retries, stats.epochs
    );

    cl.dir.check_invariants().unwrap();
    let mut short = 0;
    for idx in 0..cl.dir.len() {
        let chain = cl.dir.chain(idx);
        assert!(!chain.contains(&3) && !chain.contains(&9), "failed node still chained");
        if chain.len() < replication {
            short += 1;
        }
    }
    println!("chains below full replication after repair: {short}/{}", cl.dir.len());
    assert_eq!(short, 0, "re-replication restores r={replication}");
    assert_eq!(
        cl.metrics.completed(),
        2_000 * 4,
        "every request eventually completes despite 2 node failures"
    );
    println!("\nfailure_recovery OK");
}
