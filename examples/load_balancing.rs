//! Load balancing demo (paper §5.1): a zipf-1.2 workload concentrates load
//! on a few chains; the controller's per-epoch statistics reports trigger
//! greedy hot-range migrations to under-utilized nodes. Compares node-load
//! spread with the controller's migration on vs off.
//!
//!     cargo run --release --offline --example load_balancing

use turbokv::cluster::Cluster;
use turbokv::config::Config;

fn run(migration: bool) -> (f64, f64, u64, Vec<u64>) {
    let mut cfg = Config::default();
    cfg.workload.zipf_theta = Some(1.2);
    cfg.workload.ops_per_client = 2_500;
    cfg.controller.migration = migration;
    cfg.controller.epoch_ns = 400_000_000;
    cfg.controller.overload_factor = 1.3;
    let mut cl = Cluster::build(cfg);
    let stats = cl.run().expect("run failed");
    let served: Vec<u64> = cl.nodes.iter().map(|n| n.ops_applied).collect();
    (
        cl.metrics.throughput(),
        cl.metrics.latency_stats_ms(turbokv::types::OpCode::Get).unwrap().2,
        stats.migrations,
        served,
    )
}

fn spread(served: &[u64]) -> f64 {
    let max = *served.iter().max().unwrap() as f64;
    let mean = served.iter().sum::<u64>() as f64 / served.len() as f64;
    max / mean
}

fn main() {
    println!("zipf-1.2 read-only workload, in-switch coordination\n");
    let (thr_off, p99_off, _, served_off) = run(false);
    println!(
        "migration OFF: throughput {thr_off:.1} ops/s, read p99 {p99_off:.1} ms, max/mean node load {:.2}",
        spread(&served_off)
    );
    let (thr_on, p99_on, migrations, served_on) = run(true);
    println!(
        "migration ON : throughput {thr_on:.1} ops/s, read p99 {p99_on:.1} ms, max/mean node load {:.2} ({migrations} migrations)",
        spread(&served_on)
    );
    println!("\nper-node ops served (on):  {served_on:?}");
    println!("per-node ops served (off): {served_off:?}");
    assert!(migrations > 0);
    assert!(
        spread(&served_on) < spread(&served_off),
        "migration should flatten the load distribution"
    );
    println!("\nload_balancing OK");
}
