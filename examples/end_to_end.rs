//! End-to-end driver (DESIGN.md §4, EXPERIMENTS.md §E2E): exercises every
//! layer of the stack on a real workload —
//!
//!   L1/L2: the AOT-compiled Pallas dataplane + load-balance artifacts,
//!          executed via PJRT on the switch/controller paths,
//!   L3:    the full DES cluster — switch hierarchy, chain replication,
//!          LSM storage nodes, controller with migration enabled,
//!
//! under a skewed read/write/scan workload, for all three coordination
//! modes, and reports the paper's headline comparison (throughput + mean
//! read latency per mode). Read replies are verified against the loaded
//! corpus. Falls back to the rust dataplane when artifacts/ is missing.
//!
//!     make artifacts && cargo run --release --offline --example end_to_end

use turbokv::cluster::Cluster;
use turbokv::config::{Config, Coordination, DataplaneMode};
use turbokv::types::OpCode;

fn main() -> anyhow::Result<()> {
    let have_artifacts =
        cfg!(feature = "pjrt") && std::path::Path::new("artifacts/manifest.json").exists();
    println!(
        "dataplane: {}",
        if have_artifacts {
            "xla (AOT Pallas artifacts via PJRT)"
        } else {
            "rust (pjrt feature off or artifacts/ missing)"
        }
    );

    let mut rows = Vec::new();
    for mode in Coordination::ALL {
        let mut cfg = Config::default();
        cfg.coordination = mode;
        cfg.workload.num_keys = 20_000;
        cfg.workload.ops_per_client = 1_500;
        cfg.workload.write_ratio = 0.2;
        cfg.workload.scan_ratio = 0.1;
        cfg.workload.zipf_theta = Some(0.99);
        cfg.controller.migration = true;
        cfg.controller.epoch_ns = 1_000_000_000;
        if have_artifacts && mode == Coordination::InSwitch {
            cfg.dataplane.mode = DataplaneMode::Xla;
        }
        let t0 = std::time::Instant::now();
        let mut cl = Cluster::build_auto(cfg)?;
        cl.verify_reads = true;
        let stats = cl.run()?;
        let (read_mean, _, read_p99) =
            cl.metrics.latency_stats_ms(OpCode::Get).unwrap_or((0.0, 0.0, 0.0));
        println!(
            "[{}] completed {} ops in {:.1}s wall ({} sim events, {} migrations)",
            mode.name(),
            cl.metrics.completed(),
            t0.elapsed().as_secs_f64(),
            stats.events,
            stats.migrations,
        );
        assert_eq!(cl.verify_failures, 0, "read verification");
        rows.push((mode.name(), cl.metrics.throughput(), read_mean, read_p99));
    }

    println!("\nmode            throughput(ops/s)  read-mean(ms)  read-p99(ms)");
    for (name, thr, mean, p99) in &rows {
        println!("{name:<15} {thr:>17.1} {mean:>14.1} {p99:>13.1}");
    }
    let turbokv = rows[0].1;
    let server = rows[2].1;
    println!(
        "\nTurboKV vs server-driven: {:+.1}% throughput (paper: +26..+47%)",
        (turbokv / server - 1.0) * 100.0
    );
    assert!(turbokv > server, "in-switch must beat server-driven");
    println!("end_to_end OK");
    Ok(())
}
