"""AOT: lower the L2 graphs to HLO *text* artifacts for the rust runtime.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly.  See /opt/xla-example/gen_hlo.py.

Usage:  python -m compile.aot --outdir ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_artifacts(outdir: str) -> dict:
    os.makedirs(outdir, exist_ok=True)
    manifest = {"batch": model.BATCH, "num_ranges": model.NUM_RANGES,
                "num_nodes": model.NUM_NODES, "artifacts": {}}

    # 1. Switch dataplane: batched lookup + counter deltas.
    lowered = jax.jit(model.dataplane_step).lower(
        _spec((model.BATCH,), jnp.uint32),
        _spec((model.BATCH,), jnp.uint32),
        _spec((model.NUM_RANGES,), jnp.uint32),
    )
    path = os.path.join(outdir, "dataplane.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    manifest["artifacts"]["dataplane"] = {
        "file": "dataplane.hlo.txt",
        "inputs": [
            {"name": "keys", "shape": [model.BATCH], "dtype": "u32"},
            {"name": "ops", "shape": [model.BATCH], "dtype": "u32"},
            {"name": "starts", "shape": [model.NUM_RANGES], "dtype": "u32"},
        ],
        "outputs": [
            {"name": "idx", "shape": [model.BATCH], "dtype": "s32"},
            {"name": "read_hits", "shape": [model.NUM_RANGES], "dtype": "s32"},
            {"name": "write_hits", "shape": [model.NUM_RANGES], "dtype": "s32"},
        ],
    }

    # 2. Controller load estimate.
    lowered = jax.jit(model.load_estimate).lower(
        _spec((model.NUM_RANGES,), jnp.float32),
        _spec((model.NUM_RANGES,), jnp.float32),
        _spec((model.NUM_RANGES, model.NUM_NODES), jnp.float32),
        _spec((model.NUM_RANGES, model.NUM_NODES), jnp.float32),
        _spec((), jnp.float32),
    )
    path = os.path.join(outdir, "loadbalance.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    manifest["artifacts"]["loadbalance"] = {
        "file": "loadbalance.hlo.txt",
        "inputs": [
            {"name": "read", "shape": [model.NUM_RANGES], "dtype": "f32"},
            {"name": "write", "shape": [model.NUM_RANGES], "dtype": "f32"},
            {"name": "tail_onehot", "shape": [model.NUM_RANGES, model.NUM_NODES], "dtype": "f32"},
            {"name": "member_onehot", "shape": [model.NUM_RANGES, model.NUM_NODES], "dtype": "f32"},
            {"name": "write_cost", "shape": [], "dtype": "f32"},
        ],
        "outputs": [
            {"name": "node_load", "shape": [model.NUM_NODES], "dtype": "f32"},
            {"name": "node_share", "shape": [model.NUM_NODES], "dtype": "f32"},
        ],
    }

    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    args = ap.parse_args()
    manifest = build_artifacts(args.outdir)
    for name, art in manifest["artifacts"].items():
        full = os.path.join(args.outdir, art["file"])
        print(f"wrote {name}: {full} ({os.path.getsize(full)} bytes)")


if __name__ == "__main__":
    main()
