"""L2: the jax compute graphs the rust coordinator executes via PJRT.

Two entry points, both calling the L1 Pallas kernels:

* ``dataplane_step`` — the switch's batched match-action stage: one call
  routes a 256-key batch and returns the per-range read/write counter
  deltas (paper sections 4.1.3, 5.1).
* ``load_estimate`` — the controller's per-node load estimate from the
  counters collected in an epoch (paper section 5.1).

``python/compile/aot.py`` lowers both once to HLO text in ``artifacts/``;
python is never on the request path.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import load_matmul, range_lookup

# Shapes fixed at AOT time; rust reads these from artifacts/manifest.json.
BATCH = 256  # keys per dataplane invocation (rust pads with OP_PAD)
NUM_RANGES = 128  # index-table records (paper section 8: "128 records index table")
NUM_NODES = 16  # storage nodes (paper Fig. 12)


def dataplane_step(keys, ops, starts):
    """Batched key-based routing + query-statistics deltas.

    Args:
      keys: uint32[BATCH] top-32-bit key prefixes.
      ops: uint32[BATCH] opcodes (0 read, 1 write, 2 pad).
      starts: uint32[NUM_RANGES] sorted sub-range start boundaries.

    Returns:
      (idx int32[BATCH], read_hits int32[NUM_RANGES], write_hits int32[NUM_RANGES])
    """
    return range_lookup.range_lookup(keys, ops, starts)


def load_estimate(read, write, tail_onehot, member_onehot, write_cost):
    """Controller node-load estimate; see kernels/load_matmul.py."""
    loads = load_matmul.load_estimate(
        read, write, tail_onehot, member_onehot, write_cost
    )
    # Normalised share of total load per node — the controller's greedy
    # migration compares these shares against 1/NUM_NODES.
    total = jnp.maximum(jnp.sum(loads), 1.0)
    return loads, loads / total
