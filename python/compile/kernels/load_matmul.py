"""L1 Pallas kernel: controller node-load estimation.

TurboKV's controller (paper section 5.1) turns the per-range read/write
counters reported by the switches into a per-storage-node load estimate.
Under chain replication a read for range ``n`` lands only on the chain's
*tail* node, while a write is processed by *every* chain member (section
4.1.2), so with one-hot chain-membership matrices:

    node_load[s] = sum_n read[n]  * tail_onehot[n, s]
                 + sum_n write[n] * member_onehot[n, s] * write_cost

i.e. two small (1, N) x (N, S) matmuls — the MXU-shaped piece of the
controller.  ``write_cost`` models the relative cost of an update against a
read (each replica applies the write).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _load_kernel(read_ref, write_ref, tail_ref, member_ref, cost_ref, out_ref):
    read = read_ref[...]  # (1, n) f32
    write = write_ref[...]  # (1, n) f32
    tail = tail_ref[...]  # (n, s) f32
    member = member_ref[...]  # (n, s) f32
    cost = cost_ref[0, 0]  # scalar write cost
    out_ref[...] = jnp.dot(read, tail) + cost * jnp.dot(write, member)


@jax.jit
def load_estimate(read, write, tail_onehot, member_onehot, write_cost):
    """Per-node load estimate from per-range counters.

    Args:
      read: f32[N] read hits per range.
      write: f32[N] write hits per range.
      tail_onehot: f32[N, S]; [n, s] == 1 iff node s is the tail of range n's chain.
      member_onehot: f32[N, S]; [n, s] == 1 iff node s is in range n's chain.
      write_cost: f32[] relative cost of one write application vs one read.

    Returns:
      f32[S] estimated load per storage node.
    """
    n, s = tail_onehot.shape
    out = pl.pallas_call(
        _load_kernel,
        out_shape=jax.ShapeDtypeStruct((1, s), jnp.float32),
        interpret=True,
    )(
        read.reshape(1, n),
        write.reshape(1, n),
        tail_onehot,
        member_onehot,
        write_cost.reshape(1, 1),
    )
    return out.reshape(s)
