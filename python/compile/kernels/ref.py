"""Pure-jnp correctness oracles for the Pallas kernels.

These are the specification: the Pallas kernels in range_lookup.py /
load_matmul.py and the rust fallback in rust/src/switch/lookup.rs must all
agree with these functions bit-for-bit (integers) / to float tolerance.
"""

from __future__ import annotations

import jax.numpy as jnp

from .range_lookup import OP_PAD, OP_READ, OP_WRITE  # noqa: F401 (re-export)


def range_lookup_ref(keys, ops, starts):
    """searchsorted-based oracle for the switch range match.

    idx[b] = index of the sub-range whose [start, next_start) interval
    contains keys[b]; read/write hit histograms exclude OP_PAD slots.
    """
    keys = jnp.asarray(keys, dtype=jnp.uint32)
    ops = jnp.asarray(ops, dtype=jnp.uint32)
    starts = jnp.asarray(starts, dtype=jnp.uint32)
    n = starts.shape[0]
    idx = jnp.searchsorted(starts, keys, side="right").astype(jnp.int32) - 1
    read_hits = jnp.bincount(
        jnp.where(ops == OP_READ, idx, n), length=n + 1
    )[:n].astype(jnp.int32)
    write_hits = jnp.bincount(
        jnp.where(ops == OP_WRITE, idx, n), length=n + 1
    )[:n].astype(jnp.int32)
    return idx, read_hits, write_hits


def load_estimate_ref(read, write, tail_onehot, member_onehot, write_cost):
    """Oracle for the controller's node-load estimate."""
    read = jnp.asarray(read, dtype=jnp.float32)
    write = jnp.asarray(write, dtype=jnp.float32)
    return read @ jnp.asarray(tail_onehot, jnp.float32) + jnp.asarray(
        write_cost, jnp.float32
    ) * (write @ jnp.asarray(member_onehot, jnp.float32))
