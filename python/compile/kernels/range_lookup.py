"""L1 Pallas kernel: the switch data plane's batched range match-action stage.

This is the TPU re-think of TurboKV's Tofino range match (DESIGN.md
section "Hardware-Adaptation"): instead of one TCAM lookup per packet per
pipeline pass, a *batch* of B key prefixes is matched against all N sub-range
start boundaries as a dense (B, N) broadcast compare + reduce.  The same
one-hot matrix, masked by opcode, yields the per-range read/write hit
counters that the paper keeps in the switch's register arrays (section 5.1).

Matching semantics (identical to the rust fallback and to ref.py):

    idx[b]        = (number of n with starts[n] <= keys[b]) - 1
    read_hits[n]  = |{b : idx[b] == n and ops[b] == OP_READ}|
    write_hits[n] = |{b : idx[b] == n and ops[b] == OP_WRITE}|

``starts`` must be sorted ascending with ``starts[0] == 0`` so every key
matches exactly one sub-range (the paper's index table partitions the whole
key span).  Keys are the top 32 bits of the 128-bit TurboKV key; the
controller only splits ranges on 2^96-aligned boundaries so this prefix is
lossless.

Padding: ``ops[b] == OP_PAD`` marks an unused batch slot.  Padded slots still
produce an ``idx`` (harmless) but are excluded from both histograms.

The kernel is tiled over the batch dimension: each grid step loads a
``(block_b,)`` slice of keys/ops into VMEM together with the full ``starts``
vector, and accumulates the histogram outputs across grid steps (the
standard Pallas reduction idiom: initialize at program_id 0, add thereafter).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

OP_READ = 0
OP_WRITE = 1
OP_PAD = 2

DEFAULT_BLOCK_B = 128


def _lookup_kernel(keys_ref, ops_ref, starts_ref, idx_ref, rhits_ref, whits_ref):
    """One grid step: match a block of keys against all N boundaries."""
    keys = keys_ref[...]  # (block_b,) uint32
    ops = ops_ref[...]  # (block_b,) uint32
    starts = starts_ref[...]  # (n,) uint32

    # Dense compare: ge[b, n] = keys[b] >= starts[n].  This is the VPU
    # analogue of the TCAM range match — one row per packet in the batch.
    ge = keys[:, None] >= starts[None, :]  # (block_b, n) bool
    idx = jnp.sum(ge.astype(jnp.int32), axis=1) - 1  # (block_b,)
    idx_ref[...] = idx

    # One-hot of the matched range, masked by opcode, column-summed to give
    # this block's contribution to the per-range counters.
    n = starts.shape[0]
    onehot = idx[:, None] == jnp.arange(n, dtype=jnp.int32)[None, :]
    is_read = (ops == OP_READ)[:, None]
    is_write = (ops == OP_WRITE)[:, None]
    r_delta = jnp.sum((onehot & is_read).astype(jnp.int32), axis=0)
    w_delta = jnp.sum((onehot & is_write).astype(jnp.int32), axis=0)

    # Accumulate across grid steps: zero the counters on the first block.
    @pl.when(pl.program_id(0) == 0)
    def _init():
        rhits_ref[...] = jnp.zeros_like(rhits_ref)
        whits_ref[...] = jnp.zeros_like(whits_ref)

    rhits_ref[...] += r_delta
    whits_ref[...] += w_delta


@functools.partial(jax.jit, static_argnames=("block_b",))
def range_lookup(keys, ops, starts, *, block_b: int = DEFAULT_BLOCK_B):
    """Batched switch-dataplane lookup.

    Args:
      keys: uint32[B] key prefixes (top 32 bits of the 128-bit key).
      ops: uint32[B] opcodes (OP_READ / OP_WRITE / OP_PAD).
      starts: uint32[N] sorted sub-range start boundaries, starts[0] == 0.
      block_b: batch tile size (must divide B).

    Returns:
      (idx int32[B], read_hits int32[N], write_hits int32[N]).
    """
    b = keys.shape[0]
    n = starts.shape[0]
    if b % block_b != 0:
        raise ValueError(f"batch {b} not a multiple of block_b {block_b}")
    grid = (b // block_b,)
    return pl.pallas_call(
        _lookup_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        interpret=True,  # CPU-PJRT target; real-TPU lowering is compile-only
    )(keys, ops, starts)
