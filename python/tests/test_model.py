"""L2 shape/semantics tests for model.py entry points."""

import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose, assert_array_equal

from compile import model
from compile.kernels import ref


def test_dataplane_step_shapes_and_values():
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 2**32, size=model.BATCH, dtype=np.uint32)
    ops = rng.integers(0, 3, size=model.BATCH).astype(np.uint32)
    starts = np.unique(
        rng.integers(0, 2**32, size=4 * model.NUM_RANGES, dtype=np.uint64)
    )[: model.NUM_RANGES].astype(np.uint32)
    starts[0] = 0
    idx, rh, wh = model.dataplane_step(
        jnp.asarray(keys), jnp.asarray(ops), jnp.asarray(starts)
    )
    assert idx.shape == (model.BATCH,)
    assert rh.shape == wh.shape == (model.NUM_RANGES,)
    want = ref.range_lookup_ref(keys, ops, starts)
    assert_array_equal(np.asarray(idx), np.asarray(want[0]))
    assert_array_equal(np.asarray(rh), np.asarray(want[1]))
    assert_array_equal(np.asarray(wh), np.asarray(want[2]))


def test_load_estimate_share_sums_to_one():
    rng = np.random.default_rng(1)
    n, s = model.NUM_RANGES, model.NUM_NODES
    read = jnp.asarray(rng.random(n).astype(np.float32) * 50 + 1)
    write = jnp.asarray(rng.random(n).astype(np.float32) * 50)
    tail = jnp.asarray((rng.random((n, s)) < 0.2).astype(np.float32))
    member = jnp.maximum(tail, jnp.asarray((rng.random((n, s)) < 0.2).astype(np.float32)))
    loads, share = model.load_estimate(read, write, tail, member, jnp.float32(3.0))
    assert loads.shape == share.shape == (s,)
    assert_allclose(float(jnp.sum(share)), 1.0, rtol=1e-5)


def test_load_estimate_zero_counters_no_nan():
    n, s = model.NUM_RANGES, model.NUM_NODES
    z = jnp.zeros(n, jnp.float32)
    m = jnp.zeros((n, s), jnp.float32)
    loads, share = model.load_estimate(z, z, m, m, jnp.float32(1.0))
    assert not bool(jnp.any(jnp.isnan(share)))
