"""L1 correctness: Pallas kernels vs the pure-jnp oracles in ref.py.

Hypothesis sweeps shapes and values; fixed cases pin the paper's exact
configuration (B=256, N=128).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st
from numpy.testing import assert_allclose, assert_array_equal

from compile.kernels import load_matmul, range_lookup, ref

OPS = [ref.OP_READ, ref.OP_WRITE, ref.OP_PAD]


def make_starts(rng: np.random.Generator, n: int) -> np.ndarray:
    """Sorted unique uint32 boundaries with starts[0] == 0."""
    rest = np.unique(rng.integers(1, 2**32, size=4 * n + 8, dtype=np.uint64))[: n - 1]
    assert rest.size == n - 1
    return np.concatenate([[0], np.sort(rest)]).astype(np.uint32)


def run_both(keys, ops, starts, block_b):
    got = range_lookup.range_lookup(
        jnp.asarray(keys), jnp.asarray(ops), jnp.asarray(starts), block_b=block_b
    )
    want = ref.range_lookup_ref(keys, ops, starts)
    for g, w, name in zip(got, want, ["idx", "read_hits", "write_hits"]):
        assert_array_equal(np.asarray(g), np.asarray(w), err_msg=name)
    return got


class TestRangeLookupFixed:
    def test_paper_config_uniform(self):
        """B=256, N=128 — the AOT shapes."""
        rng = np.random.default_rng(7)
        starts = make_starts(rng, 128)
        keys = rng.integers(0, 2**32, size=256, dtype=np.uint32)
        ops = rng.integers(0, 2, size=256).astype(np.uint32)
        run_both(keys, ops, starts, block_b=128)

    def test_boundary_keys_match_their_own_range(self):
        starts = np.array([0, 100, 200, 300], dtype=np.uint32)
        keys = np.array([0, 99, 100, 199, 200, 300, 2**32 - 1, 150], dtype=np.uint32)
        ops = np.zeros(8, dtype=np.uint32)
        idx, rh, wh = run_both(keys, ops, starts, block_b=8)
        assert_array_equal(np.asarray(idx), [0, 0, 1, 1, 2, 3, 3, 1])
        assert_array_equal(np.asarray(rh), [2, 3, 1, 2])
        assert_array_equal(np.asarray(wh), [0, 0, 0, 0])

    def test_pad_slots_excluded_from_histograms(self):
        starts = np.array([0, 10], dtype=np.uint32)
        keys = np.array([5, 15, 15, 5], dtype=np.uint32)
        ops = np.array([ref.OP_PAD, ref.OP_READ, ref.OP_WRITE, ref.OP_PAD], dtype=np.uint32)
        _, rh, wh = run_both(keys, ops, starts, block_b=4)
        assert_array_equal(np.asarray(rh), [0, 1])
        assert_array_equal(np.asarray(wh), [0, 1])

    def test_all_keys_first_range(self):
        starts = np.array([0, 2**31], dtype=np.uint32)
        keys = np.zeros(16, dtype=np.uint32)
        ops = np.zeros(16, dtype=np.uint32)
        idx, rh, wh = run_both(keys, ops, starts, block_b=8)
        assert int(np.asarray(rh)[0]) == 16

    def test_single_range_table(self):
        starts = np.array([0], dtype=np.uint32)
        keys = np.array([0, 1, 2**32 - 1, 77], dtype=np.uint32)
        ops = np.array([0, 1, 0, 1], dtype=np.uint32)
        idx, rh, wh = run_both(keys, ops, starts, block_b=4)
        assert_array_equal(np.asarray(idx), [0, 0, 0, 0])
        assert int(np.asarray(rh)[0]) == 2 and int(np.asarray(wh)[0]) == 2

    def test_counter_totals_conserved(self):
        rng = np.random.default_rng(11)
        starts = make_starts(rng, 32)
        keys = rng.integers(0, 2**32, size=512, dtype=np.uint32)
        ops = rng.integers(0, 3, size=512).astype(np.uint32)
        _, rh, wh = run_both(keys, ops, starts, block_b=64)
        assert int(np.asarray(rh).sum()) == int((ops == ref.OP_READ).sum())
        assert int(np.asarray(wh).sum()) == int((ops == ref.OP_WRITE).sum())

    def test_rejects_non_multiple_batch(self):
        starts = np.array([0], dtype=np.uint32)
        with pytest.raises(ValueError):
            range_lookup.range_lookup(
                jnp.zeros(10, jnp.uint32), jnp.zeros(10, jnp.uint32),
                jnp.asarray(starts), block_b=8,
            )


class TestRangeLookupHypothesis:
    # Shapes are drawn from small fixed sets so jax's jit cache is hit and the
    # sweep stays fast; values still vary freely across examples.
    @settings(max_examples=30, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        n=st.sampled_from([1, 2, 16, 128]),
        blocks=st.sampled_from([1, 2]),
        block_b=st.sampled_from([8, 128]),
    )
    def test_matches_ref_random(self, seed, n, blocks, block_b):
        rng = np.random.default_rng(seed)
        starts = make_starts(rng, n) if n > 1 else np.zeros(1, dtype=np.uint32)
        b = blocks * block_b
        keys = rng.integers(0, 2**32, size=b, dtype=np.uint32)
        ops = rng.integers(0, 3, size=b).astype(np.uint32)
        run_both(keys, ops, starts, block_b=block_b)

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n=st.sampled_from([2, 16, 64]))
    def test_keys_on_exact_boundaries(self, seed, n):
        """Keys equal to boundary values land in the range they start."""
        rng = np.random.default_rng(seed)
        starts = make_starts(rng, n)
        keys = np.resize(starts, 64).astype(np.uint32)
        ops = np.zeros(64, dtype=np.uint32)
        idx, _, _ = run_both(keys, ops, starts, block_b=32)
        for k, i in zip(keys, np.asarray(idx)):
            assert starts[i] <= k
            if i + 1 < n:
                assert k < starts[i + 1]


class TestLoadMatmul:
    def test_paper_config(self):
        rng = np.random.default_rng(3)
        n, s = 128, 16
        read = rng.random(n).astype(np.float32) * 1000
        write = rng.random(n).astype(np.float32) * 1000
        tail = np.zeros((n, s), np.float32)
        member = np.zeros((n, s), np.float32)
        for r in range(n):
            chain = rng.choice(s, size=3, replace=False)
            member[r, chain] = 1.0
            tail[r, chain[-1]] = 1.0
        cost = jnp.float32(3.0)
        got = load_matmul.load_estimate(
            jnp.asarray(read), jnp.asarray(write), jnp.asarray(tail),
            jnp.asarray(member), cost,
        )
        want = ref.load_estimate_ref(read, write, tail, member, 3.0)
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)

    @settings(max_examples=20, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        n=st.sampled_from([1, 16, 128]),
        s=st.sampled_from([1, 4, 16]),
        cost=st.floats(0.0, 10.0, allow_nan=False),
    )
    def test_matches_ref_random(self, seed, n, s, cost):
        rng = np.random.default_rng(seed)
        read = rng.random(n).astype(np.float32) * 100
        write = rng.random(n).astype(np.float32) * 100
        tail = (rng.random((n, s)) < 0.3).astype(np.float32)
        member = np.maximum(tail, (rng.random((n, s)) < 0.3).astype(np.float32))
        got = load_matmul.load_estimate(
            jnp.asarray(read), jnp.asarray(write), jnp.asarray(tail),
            jnp.asarray(member), jnp.float32(cost),
        )
        want = ref.load_estimate_ref(read, write, tail, member, cost)
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)

    def test_zero_counters_zero_load(self):
        z = jnp.zeros(8, jnp.float32)
        m = jnp.ones((8, 4), jnp.float32)
        got = load_matmul.load_estimate(z, z, m, m, jnp.float32(5.0))
        assert_array_equal(np.asarray(got), np.zeros(4, np.float32))
