"""AOT path: HLO text artifacts are produced, parse as HLO, match manifest."""

import json
import os

from compile import aot, model


def test_build_artifacts(tmp_path):
    outdir = str(tmp_path)
    manifest = aot.build_artifacts(outdir)
    assert set(manifest["artifacts"]) == {"dataplane", "loadbalance"}
    for art in manifest["artifacts"].values():
        path = os.path.join(outdir, art["file"])
        assert os.path.exists(path)
        text = open(path).read()
        # HLO text sanity: module header + entry computation present.
        assert text.startswith("HloModule")
        assert "ENTRY" in text
    with open(os.path.join(outdir, "manifest.json")) as f:
        on_disk = json.load(f)
    assert on_disk["batch"] == model.BATCH
    assert on_disk["num_ranges"] == model.NUM_RANGES
    assert on_disk["num_nodes"] == model.NUM_NODES


def test_dataplane_hlo_has_expected_signature(tmp_path):
    aot.build_artifacts(str(tmp_path))
    text = open(os.path.join(str(tmp_path), "dataplane.hlo.txt")).read()
    # Entry layout should mention the three u32 inputs and tuple output.
    assert f"u32[{model.BATCH}]" in text
    assert f"u32[{model.NUM_RANGES}]" in text
    assert f"s32[{model.BATCH}]" in text
